"""Fused uHD encode+bundle Pallas kernel (the paper's core operation).

Computes hv[b,d] = sum_h (2*[x[b,h] >= S[h,d]] - 1) without ever
materializing the (B, H, D) level-hypervector tensor in HBM — the TPU
analogue of the paper's multiplier-less, position-free encoding
(contributions 1-2): the only HBM traffic is the quantized inputs and
the (B, D) accumulator.

Tiling: grid (B/bt, D/dt, H/ht); the H axis is the reduction — the
output block index_map ignores it, so the accumulator block stays
resident in VMEM across the H sweep (initialized at h==0).  The compare
broadcast (bt, ht, dt) lives entirely in VREG/VMEM; ht*dt is chosen so
the working set (x tile + sobol tile + compare cube + acc) fits VMEM
comfortably: 8*128*512*4B ≈ 2 MiB.

A `generate_sobol` variant regenerates the Sobol tile *inside* the
kernel from the (H, 32) direction matrix (Gray-code XOR), eliminating
the (H, D) threshold table from HBM entirely — the TPU mapping of the
paper's "dynamic generation instead of stored tables" theme.  See
ops.encode_bundle_dynamic, registered as the "pallas" backend of the
"uhd_dynamic" encoder.

The `fit_bundle*` kernels below fuse one more stage: per-class bundling
(training).  Their grid is (D/dt, B/bt, H/ht) — the D axis outermost so
each (C, dt) class-sum block stays resident in VMEM across the full
(B, H) sweep, with *both* batch and feature axes folded into the
accumulator.  The (B, D) hypervector batch therefore never exists in
HBM, even tiled: the only HBM traffic of a training step is the
quantized inputs, the label indicator, the encoder state (threshold
tile or direction matrix) and the (C, D) class sums (DESIGN.md §9).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_bundle_kernel(x_ref, s_ref, o_ref, *, ht: int):
    """x (bt, ht) int32, s (ht, dt) int32 -> accumulate o (bt, dt) int32."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ge = x_ref[...][:, :, None] >= s_ref[...][None, :, :]  # (bt, ht, dt)
    contrib = 2 * ge.sum(axis=1, dtype=jnp.int32) - ht
    o_ref[...] += contrib


def encode_bundle_pallas(
    x_q: jax.Array,
    sobol_q: jax.Array,
    *,
    block_b: int = 8,
    block_h: int = 112,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Launch the fused encode+bundle kernel.

    Requires B % block_b == H % block_h == D % block_d == 0 (the ops.py
    wrapper pads and corrects).  Returns (B, D) int32.
    """
    b, h = x_q.shape
    h2, d = sobol_q.shape
    assert h == h2, (h, h2)
    assert b % block_b == 0 and h % block_h == 0 and d % block_d == 0

    grid = (b // block_b, d // block_d, h // block_h)
    return pl.pallas_call(
        functools.partial(_encode_bundle_kernel, ht=block_h),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_h), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_h, block_d), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.int32),
        interpret=interpret,
    )(x_q.astype(jnp.int32), sobol_q.astype(jnp.int32))


def _encode_bundle_dyn_kernel(
    x_ref, dir_ref, o_ref, *, ht: int, block_d: int, shift: int, skip: int, n_bits: int
):
    """Sobol-free variant: thresholds are generated in VMEM from the
    direction matrix (dir_ref: (ht, n_bits) uint32) via Gray-code XOR.
    `shift` right-shifts raw 32-bit Sobol integers to quantized levels
    (0 when the direction numbers are pre-quantized).  `skip` offsets
    the point index so the generated sequence matches a table built
    with the same ``sobol_skip`` bit-for-bit.
    """
    k = pl.program_id(2)
    j = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Generate the (ht, dt) quantized Sobol tile for points
    # [skip + j*dt, skip + (j+1)*dt) — `skip` drops the leading points,
    # point 0 (all zeros) being degenerate, exactly like the table path.
    idx = (j * block_d + jax.lax.iota(jnp.uint32, block_d)) + jnp.uint32(skip)
    gray = idx ^ (idx >> jnp.uint32(1))
    acc = jnp.zeros((dir_ref.shape[0], block_d), jnp.uint32)
    dirs = dir_ref[...]
    for bit in range(n_bits):
        mask = ((gray >> jnp.uint32(bit)) & jnp.uint32(1)).astype(jnp.uint32)
        acc = acc ^ (mask[None, :] * dirs[:, bit : bit + 1])
    s = (acc >> jnp.uint32(shift)).astype(jnp.int32)

    ge = x_ref[...][:, :, None] >= s[None, :, :]
    o_ref[...] += 2 * ge.sum(axis=1, dtype=jnp.int32) - ht


def encode_bundle_dynamic_pallas(
    x_q: jax.Array,
    direction: jax.Array,
    d: int,
    *,
    shift: int = 0,
    skip: int = 1,
    block_b: int = 8,
    block_h: int = 112,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused encode+bundle with in-kernel Sobol generation.

    x_q: (B, H) int32; direction: (H, n_bits) uint direction integers
    (raw 32-bit with ``shift = 32 - M``, or M-bit pre-quantized with
    ``shift = 0``); `d` = hypervector dimensionality (number of Sobol
    points generated), `skip` = leading points dropped (``sobol_skip``).
    HBM traffic drops from O(H*D) (threshold table) to O(H*n_bits).
    """
    b, h = x_q.shape
    h2, n_bits = direction.shape
    assert h == h2
    assert b % block_b == 0 and h % block_h == 0 and d % block_d == 0

    grid = (b // block_b, d // block_d, h // block_h)
    return pl.pallas_call(
        functools.partial(
            _encode_bundle_dyn_kernel,
            ht=block_h,
            block_d=block_d,
            shift=shift,
            skip=skip,
            n_bits=n_bits,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_h), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_h, n_bits), lambda i, j, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.int32),
        interpret=interpret,
    )(x_q.astype(jnp.int32), direction.astype(jnp.uint32))


def _fit_bundle_kernel(x_ref, s_ref, oh_ref, o_ref, *, ht: int):
    """x (bt, ht) i32, s (ht, dt) i32, oh (cp, bt) i32 -> acc o (cp, dt).

    The (bt, dt) hypervector slab lives only in VREG/VMEM; it is
    contracted against the label indicator in int32 (exact) before the
    next grid step overwrites it.
    """
    i = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((i == 0) & (k == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ge = x_ref[...][:, :, None] >= s_ref[...][None, :, :]  # (bt, ht, dt)
    hv = 2 * ge.sum(axis=1, dtype=jnp.int32) - ht  # (bt, dt)
    oh = oh_ref[...]  # (cp, bt)
    o_ref[...] += (oh[:, :, None] * hv[None, :, :]).sum(axis=1, dtype=jnp.int32)


def fit_bundle_pallas(
    x_q: jax.Array,
    sobol_q: jax.Array,
    onehot: jax.Array,
    *,
    block_b: int = 8,
    block_h: int = 112,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused encode+bundle+class-sum over a threshold table.

    x_q: (B, H) int32, sobol_q: (H, D) int32, onehot: (C, B) int32.
    Requires B/H/D divisible by their blocks (ops.py pads + corrects);
    C rides whole in one block.  Returns (C, D) int32 class sums.
    """
    b, h = x_q.shape
    h2, d = sobol_q.shape
    c = onehot.shape[0]
    assert h == h2 and onehot.shape[1] == b
    assert b % block_b == 0 and h % block_h == 0 and d % block_d == 0

    grid = (d // block_d, b // block_b, h // block_h)
    return pl.pallas_call(
        functools.partial(_fit_bundle_kernel, ht=block_h),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_h), lambda j, i, k: (i, k)),
            pl.BlockSpec((block_h, block_d), lambda j, i, k: (k, j)),
            pl.BlockSpec((c, block_b), lambda j, i, k: (0, i)),
        ],
        out_specs=pl.BlockSpec((c, block_d), lambda j, i, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((c, d), jnp.int32),
        interpret=interpret,
    )(x_q.astype(jnp.int32), sobol_q.astype(jnp.int32), onehot.astype(jnp.int32))


def _fit_bundle_dyn_kernel(
    x_ref, dir_ref, oh_ref, skip_ref, o_ref, *, ht: int, block_d: int, shift: int,
    n_bits: int,
):
    """Table-free fit_bundle: thresholds generated in VMEM per D-tile.

    `skip_ref` is a (1, 1) int32 *runtime* scalar (unlike the static
    `skip` of the encode kernel): under D-axis sharding each shard
    passes ``sobol_skip + axis_index * d_local``, which is traced — so
    the first generated point index must be data, not a compile-time
    constant.
    """
    j = pl.program_id(0)
    i = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((i == 0) & (k == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    idx = (j * block_d + jax.lax.iota(jnp.uint32, block_d)) + skip_ref[
        0, 0
    ].astype(jnp.uint32)
    gray = idx ^ (idx >> jnp.uint32(1))
    acc = jnp.zeros((dir_ref.shape[0], block_d), jnp.uint32)
    dirs = dir_ref[...]
    for bit in range(n_bits):
        mask = ((gray >> jnp.uint32(bit)) & jnp.uint32(1)).astype(jnp.uint32)
        acc = acc ^ (mask[None, :] * dirs[:, bit : bit + 1])
    s = (acc >> jnp.uint32(shift)).astype(jnp.int32)

    ge = x_ref[...][:, :, None] >= s[None, :, :]
    hv = 2 * ge.sum(axis=1, dtype=jnp.int32) - ht
    oh = oh_ref[...]
    o_ref[...] += (oh[:, :, None] * hv[None, :, :]).sum(axis=1, dtype=jnp.int32)


def fit_bundle_dynamic_pallas(
    x_q: jax.Array,
    direction: jax.Array,
    onehot: jax.Array,
    skip: jax.Array,
    d: int,
    *,
    shift: int = 0,
    block_b: int = 8,
    block_h: int = 112,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused encode+bundle+class-sum with in-kernel Sobol generation.

    x_q: (B, H) int32; direction: (H, n_bits) uint; onehot: (C, B) int32;
    skip: (1, 1) int32 first-point index (may be traced — see the kernel
    docstring).  Returns (C, d) int32 class sums; neither the (H, D)
    threshold table nor the (B, D) hypervector batch ever touches HBM.
    """
    b, h = x_q.shape
    h2, n_bits = direction.shape
    c = onehot.shape[0]
    assert h == h2 and onehot.shape[1] == b
    assert b % block_b == 0 and h % block_h == 0 and d % block_d == 0

    grid = (d // block_d, b // block_b, h // block_h)
    return pl.pallas_call(
        functools.partial(
            _fit_bundle_dyn_kernel,
            ht=block_h,
            block_d=block_d,
            shift=shift,
            n_bits=n_bits,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_h), lambda j, i, k: (i, k)),
            pl.BlockSpec((block_h, n_bits), lambda j, i, k: (k, 0)),
            pl.BlockSpec((c, block_b), lambda j, i, k: (0, i)),
            pl.BlockSpec((1, 1), lambda j, i, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((c, block_d), lambda j, i, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((c, d), jnp.int32),
        interpret=interpret,
    )(
        x_q.astype(jnp.int32),
        direction.astype(jnp.uint32),
        onehot.astype(jnp.int32),
        jnp.asarray(skip, jnp.int32).reshape(1, 1),
    )
