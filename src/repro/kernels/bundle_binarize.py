"""Class bundling with concurrent binarization (uHD contribution 5).

Accumulates image hypervectors into per-class sums and applies the
threshold *inside the kernel epilogue*, so the int32 accumulator never
takes an extra HBM round-trip — the bandwidth analogue of the paper's
TOB masking logic replacing a separate subtractor/comparator stage.

    sums[c, d] = sum_b onehot[c, b] * hv[b, d]      (MXU matmul)
    out[c, d]  = +1 if sums >= 0 else -1            (fused epilogue)

Grid (C/ct, D/dt, B/bt); B is the reduction axis; fp32 accumulation is
exact for counts < 2^24.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bundle_kernel(lab_ref, hv_ref, out_ref, sum_ref, *, n_b: int, binarize: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)

    sum_ref[...] += jax.lax.dot(
        lab_ref[...], hv_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_b - 1)
    def _epilogue():
        s = sum_ref[...]
        if binarize:
            out_ref[...] = jnp.where(s >= 0, 1, -1).astype(out_ref.dtype)
        else:
            out_ref[...] = s.astype(out_ref.dtype)


def bundle_binarize_pallas(
    hvs: jax.Array,
    onehot_labels: jax.Array,
    *,
    binarize: bool = True,
    block_c: int = 8,
    block_d: int = 512,
    block_b: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """hvs: (B, D) int32; onehot_labels: (C, B) float/int {0,1}.

    Returns (C, D) int8 ±1 if binarize else (C, D) int32 raw sums.
    """
    b, d = hvs.shape
    c, b2 = onehot_labels.shape
    assert b == b2
    assert c % block_c == 0 and d % block_d == 0 and b % block_b == 0
    n_b = b // block_b

    out_dtype = jnp.int8 if binarize else jnp.int32
    return pl.pallas_call(
        functools.partial(_bundle_kernel, n_b=n_b, binarize=binarize),
        grid=(c // block_c, d // block_d, n_b),
        in_specs=[
            pl.BlockSpec((block_c, block_b), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_c, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((c, d), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_d), jnp.float32)],
        interpret=interpret,
    )(onehot_labels.astype(jnp.float32), hvs.astype(jnp.float32))
