"""MXU-unary encode kernel: threshold-compare-accumulate as binary matmul.

TPU adaptation of uHD contribution 3 (unary bit-streams).  The inclusive
thermometer code U (B, H*xi) and the one-hot threshold matrix O (H*xi, D)
are binary, so

    count_ge = U @ O        (exact in bf16; values <= H*xi < 2^24)
    hv       = 2*count - H  (fused epilogue)

runs on the 128x128 MXU at matmul throughput instead of the VPU.  This
is a classic fp32-accumulator Pallas matmul: grid (B/bt, D/dt, K/kt),
accumulator scratch persists across the K sweep, epilogue applied at the
last K step before the single HBM write-back (the paper's "concurrent
binarization" idea generalized to 'concurrent affine epilogue').
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mxu_kernel(u_ref, o_ref, out_ref, *, h: int, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # Binary operands: the fp32-accumulated MXU dot is integer-exact.
    # The count accumulates in the (VMEM-resident) int32 output block.
    part = jax.lax.dot(u_ref[...], o_ref[...], preferred_element_type=jnp.float32)
    out_ref[...] += part.astype(jnp.int32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        out_ref[...] = 2 * out_ref[...] - h


def encode_unary_mxu_pallas(
    u: jax.Array,
    onehot_s: jax.Array,
    h: int,
    *,
    block_b: int = 128,
    block_d: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """u: (B, K) bf16 thermometer; onehot_s: (K, D) bf16 one-hot.

    Returns (B, D) int32 hypervectors.  Dims must divide the blocks (the
    ops.py wrapper pads with zero rows/cols, which contribute 0 to the
    count and are sliced away).
    """
    b, kdim = u.shape
    k2, d = onehot_s.shape
    assert kdim == k2
    assert b % block_b == 0 and d % block_d == 0 and kdim % block_k == 0
    n_k = kdim // block_k

    return pl.pallas_call(
        functools.partial(_mxu_kernel, h=h, n_k=n_k),
        grid=(b // block_b, d // block_d, n_k),
        in_specs=[
            pl.BlockSpec((block_b, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_d), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.int32),
        interpret=interpret,
    )(u.astype(jnp.bfloat16), onehot_s.astype(jnp.bfloat16))
