"""Pure-jnp oracles for every Pallas kernel in this package.

Each function defines the exact semantics its kernel must reproduce;
tests sweep shapes/dtypes and assert allclose (exact for the integer
kernels) against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def encode_bundle(x_q: jax.Array, sobol_q: jax.Array) -> jax.Array:
    """Fused uHD encode+bundle: hv[b,d] = sum_h (2*[x[b,h] >= S[h,d]] - 1).

    (B, H) int, (H, D) int -> (B, D) int32, values in [-H, H].
    """
    h = x_q.shape[-1]
    ge = x_q[:, :, None].astype(jnp.int32) >= sobol_q[None, :, :].astype(jnp.int32)
    return (2 * ge.sum(axis=1, dtype=jnp.int32) - h).astype(jnp.int32)


def encode_unary_mxu(u: jax.Array, onehot_s: jax.Array, h: int) -> jax.Array:
    """MXU-unary encode: 2 * (U @ O) - H with binary bf16 operands.

    u: (B, K) thermometer-coded data (K = H * levels), onehot_s: (K, D).
    Returns (B, D) int32.
    """
    count = jnp.dot(
        u.astype(jnp.float32), onehot_s.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return (2 * count - h).astype(jnp.int32)


def bundle_binarize(hvs: jax.Array, onehot_labels: jax.Array) -> jax.Array:
    """Class bundling with concurrent binarization (paper contribution 5).

    hvs: (B, D) int32 image HVs; onehot_labels: (C, B) {0,1}.
    Returns (C, D) int8 ±1 = sign of the per-class sum (ties -> +1).
    """
    sums = jnp.dot(
        onehot_labels.astype(jnp.float32), hvs.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return jnp.where(sums >= 0, jnp.int8(1), jnp.int8(-1))


def hamming_packed(q_words: jax.Array, c_words: jax.Array, d: int) -> jax.Array:
    """Packed ±1 dot via XOR+popcount: (B, W) x (C, W) -> (B, C) int32.

    score = d - 2 * popcount(q ^ c); assumes padding bits are equal in
    both operands (the packers zero them).
    """
    x = q_words[:, None, :] ^ c_words[None, :, :]
    pc = jax.lax.population_count(x).astype(jnp.int32).sum(-1)
    return d - 2 * pc


def sobol_tile(direction: jax.Array, d0: jax.Array, tile: int) -> jax.Array:
    """On-the-fly Sobol integer generation for points [d0, d0+tile).

    direction: (H, NBITS) uint32 direction integers.  Returns (H, tile)
    uint32 raw Sobol integers: point k = XOR of direction bits of gray(k).
    """
    idx = (d0 + jnp.arange(tile)).astype(jnp.uint32)
    gray = idx ^ (idx >> jnp.uint32(1))
    n_bits = direction.shape[-1]
    acc = jnp.zeros((direction.shape[0], tile), jnp.uint32)
    for b in range(n_bits):
        mask = ((gray >> jnp.uint32(b)) & jnp.uint32(1)).astype(jnp.uint32)
        acc = acc ^ (mask[None, :] * direction[:, b : b + 1])
    return acc
