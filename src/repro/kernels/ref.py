"""Pure-jnp oracles for every Pallas kernel in this package.

Each function defines the exact semantics its kernel must reproduce;
tests sweep shapes/dtypes and assert allclose (exact for the integer
kernels) against these.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def encode_bundle(x_q: jax.Array, sobol_q: jax.Array) -> jax.Array:
    """Fused uHD encode+bundle: hv[b,d] = sum_h (2*[x[b,h] >= S[h,d]] - 1).

    (B, H) int, (H, D) int -> (B, D) int32, values in [-H, H].
    """
    h = x_q.shape[-1]
    ge = x_q[:, :, None].astype(jnp.int32) >= sobol_q[None, :, :].astype(jnp.int32)
    return (2 * ge.sum(axis=1, dtype=jnp.int32) - h).astype(jnp.int32)


def encode_unary_mxu(u: jax.Array, onehot_s: jax.Array, h: int) -> jax.Array:
    """MXU-unary encode: 2 * (U @ O) - H with binary bf16 operands.

    u: (B, K) thermometer-coded data (K = H * levels), onehot_s: (K, D).
    Returns (B, D) int32.
    """
    count = jnp.dot(
        u.astype(jnp.float32), onehot_s.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return (2 * count - h).astype(jnp.int32)


def bundle_binarize(hvs: jax.Array, onehot_labels: jax.Array) -> jax.Array:
    """Class bundling with concurrent binarization (paper contribution 5).

    hvs: (B, D) int32 image HVs; onehot_labels: (C, B) {0,1}.
    Returns (C, D) int8 ±1 = sign of the per-class sum (ties -> +1).
    """
    sums = jnp.dot(
        onehot_labels.astype(jnp.float32), hvs.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return jnp.where(sums >= 0, jnp.int8(1), jnp.int8(-1))


def hamming_packed(q_words: jax.Array, c_words: jax.Array, d: int) -> jax.Array:
    """Packed ±1 dot via XOR+popcount: (B, W) x (C, W) -> (B, C) int32.

    score = d - 2 * popcount(q ^ c); assumes padding bits are equal in
    both operands (the packers zero them).
    """
    x = q_words[:, None, :] ^ c_words[None, :, :]
    pc = jax.lax.population_count(x).astype(jnp.int32).sum(-1)
    return d - 2 * pc


_I32_MAX = np.iinfo(np.int32).max


def topk_pinned(dist: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Pinned top-k over a (B, C) int32 distance matrix: the k smallest
    distances per row, ties broken by **lowest column index** — a total
    order, since indices are unique.  Returns ((B, k) int32 indices,
    (B, k) int32 distances), each row ascending by (distance, index).

    Implemented as a two-key `lax.sort` (distance primary, index
    secondary): a composite int key (dist * C + idx) would overflow
    int32 at retrieval-scale C, and plain `lax.top_k` cannot express
    the secondary key portably.
    """
    b, c = dist.shape
    if not 1 <= k <= c:
        raise ValueError(f"k must be in [1, {c}], got {k}")
    idx = jax.lax.broadcasted_iota(jnp.int32, (b, c), 1)
    sd, si = jax.lax.sort(
        (dist.astype(jnp.int32), idx), dimension=-1, num_keys=2
    )
    return si[:, :k], sd[:, :k]


def hamming_topk_oracle(
    q_words: jax.Array, c_words: jax.Array, d: int, k: int
) -> tuple[jax.Array, jax.Array]:
    """Full-argsort oracle for packed top-k retrieval.

    dist[b, c] = popcount(q[b] ^ rows[c]) (true Hamming distance over d
    dims; padding bits are zeroed by the packers and cancel in the XOR).
    Returns the k nearest rows per query as ((B, k) indices, (B, k)
    distances), pinned lowest-index-wins on ties.  Every backend
    (`ref.hamming_topk`, the Pallas kernel, the sharded psum path) must
    be bit-identical to this.
    """
    x = q_words[:, None, :] ^ c_words[None, :, :]
    pc = jax.lax.population_count(x).astype(jnp.int32).sum(-1)
    return topk_pinned(pc, k)


def hamming_topk(
    q_words: jax.Array,
    c_words: jax.Array,
    d: int,
    k: int,
    *,
    block_c: int = 4096,
) -> tuple[jax.Array, jax.Array]:
    """Tiled pure-JAX top-k: scan over (C/block_c) row tiles carrying a
    running k-best, so the full (B, C) distance matrix never
    materializes (at C=1M it would be 4 GB; the carry is (B, k)).

    Each step XOR+popcounts one tile, concatenates the tile's
    (distance, global-index) candidates onto the carry, and re-selects
    the k smallest under the pinned (distance, index) order via a
    two-key sort.  Bit-identical to `hamming_topk_oracle`.
    """
    b, w = q_words.shape
    c = c_words.shape[0]
    if not 1 <= k <= c:
        raise ValueError(f"k must be in [1, {c}], got {k}")
    # Shrink the tile to C for small stores (the predict path has C ~ 10;
    # padding it to 4096 rows would XOR 400x more than needed).
    block_c = max(1, min(block_c, c))
    n_blocks = -(-c // block_c)
    pad = n_blocks * block_c - c
    cw = jnp.pad(c_words, ((0, pad), (0, 0))).reshape(n_blocks, block_c, w)
    starts = jnp.arange(n_blocks, dtype=jnp.int32) * block_c
    init = (
        jnp.full((b, k), _I32_MAX, jnp.int32),  # distances
        jnp.full((b, k), _I32_MAX, jnp.int32),  # indices
    )

    def one(carry, inp):
        tile, start = inp
        x = q_words[:, None, :] ^ tile[None, :, :]
        pc = jax.lax.population_count(x).astype(jnp.int32).sum(-1)
        gidx = start + jax.lax.broadcasted_iota(jnp.int32, (b, block_c), 1)
        valid = gidx < c  # padded rows never win: sentinel (MAX, MAX)
        dist_t = jnp.where(valid, pc, _I32_MAX)
        gidx = jnp.where(valid, gidx, _I32_MAX)
        dists = jnp.concatenate([carry[0], dist_t], axis=1)
        idxs = jnp.concatenate([carry[1], gidx], axis=1)
        sd, si = jax.lax.sort((dists, idxs), dimension=-1, num_keys=2)
        return (sd[:, :k], si[:, :k]), None

    (dist_k, idx_k), _ = jax.lax.scan(one, init, (cw, starts))
    return idx_k, dist_k


def class_onehot(labels: jax.Array, n_classes: int) -> jax.Array:
    """(B,) int labels -> (C, B) int32 {0,1} indicator.

    A label outside [0, n_classes) produces an all-zero column — the
    jitted drop contract shared with `encoding.bundle_by_class` (the
    host-path entry points validate labels loudly before tracing).
    """
    lab = labels.astype(jnp.int32)
    return (lab[None, :] == jnp.arange(n_classes, dtype=jnp.int32)[:, None]).astype(
        jnp.int32
    )


def fit_bundle(
    x_q: jax.Array,
    sobol_q: jax.Array,
    labels: jax.Array,
    n_classes: int,
    *,
    block_d: int = 512,
) -> jax.Array:
    """Fused training hot loop, table form: encode + per-class bundling in
    one D-tile scan.  (B, H), (H, D), (B,) -> (C, D) int32 class sums.

    Each scan step materializes only a (B, tile) hypervector slab and
    immediately contracts it against the (C, B) label indicator in int32
    — the (B, D) hypervector batch never exists at once, and the class
    sums are integer-exact for any batch size.  Bit-identical to
    `encode_bundle` followed by an int32 segment sum.
    """
    b, h = x_q.shape
    d = sobol_q.shape[-1]
    x = x_q.astype(jnp.int32)
    onehot = class_onehot(labels, n_classes)
    n_blocks = -(-d // block_d)
    pad = n_blocks * block_d - d
    s = jnp.pad(
        sobol_q.astype(jnp.int32), ((0, 0), (0, pad)),
        constant_values=np.iinfo(np.int32).max,
    )
    s = jnp.moveaxis(s.reshape(h, n_blocks, block_d), 1, 0)

    def one(carry, sblk):
        ge = x[:, :, None] >= sblk[None, :, :]  # (B, H, tile)
        hv = 2 * ge.sum(axis=1, dtype=jnp.int32) - h
        return carry, jnp.einsum(
            "cb,bd->cd", onehot, hv, preferred_element_type=jnp.int32
        )

    _, out = jax.lax.scan(one, 0, s)
    return jnp.moveaxis(out, 0, 1).reshape(n_classes, -1)[:, :d]


def fit_bundle_dynamic(
    x_q: jax.Array,
    direction: jax.Array,
    labels: jax.Array,
    n_classes: int,
    d: int,
    *,
    skip: int | jax.Array = 1,
    block_d: int = 512,
) -> jax.Array:
    """Table-free fused training hot loop: Sobol thresholds regenerated
    per D-tile, encoded, and bundled into (C, d) int32 class sums — the
    only training-time state is the (H, N_BITS) direction matrix.

    `skip` is the index of the first Sobol point generated and may be a
    *traced* scalar: under D-axis sharding each host passes
    ``cfg.sobol_skip + axis_index * d_local`` so it Gray-codes only the
    points of its own D-slice.  Bit-identical to `fit_bundle` over the
    table built with the same seed/levels/skip.
    """
    b, h = x_q.shape
    x = x_q[:, :, None].astype(jnp.int32)
    dirs = direction.astype(jnp.uint32)
    onehot = class_onehot(labels, n_classes)
    n_blocks = -(-d // block_d)
    starts = jnp.asarray(skip, jnp.uint32) + jnp.arange(
        n_blocks, dtype=jnp.uint32
    ) * jnp.uint32(block_d)

    def one(carry, d0):
        s = sobol_tile(dirs, d0, block_d).astype(jnp.int32)
        ge = x >= s[None, :, :]
        hv = 2 * ge.sum(axis=1, dtype=jnp.int32) - h
        return carry, jnp.einsum(
            "cb,bd->cd", onehot, hv, preferred_element_type=jnp.int32
        )

    _, out = jax.lax.scan(one, 0, starts)
    return jnp.moveaxis(out, 0, 1).reshape(n_classes, -1)[:, :d]


def sobol_tile(direction: jax.Array, d0: jax.Array, tile: int) -> jax.Array:
    """On-the-fly Sobol integer generation for points [d0, d0+tile).

    direction: (H, NBITS) uint32 direction integers.  Returns (H, tile)
    uint32 raw Sobol integers: point k = XOR of direction bits of gray(k).
    """
    idx = (d0 + jnp.arange(tile)).astype(jnp.uint32)
    gray = idx ^ (idx >> jnp.uint32(1))
    n_bits = direction.shape[-1]
    acc = jnp.zeros((direction.shape[0], tile), jnp.uint32)
    for b in range(n_bits):
        mask = ((gray >> jnp.uint32(b)) & jnp.uint32(1)).astype(jnp.uint32)
        acc = acc ^ (mask[None, :] * direction[:, b : b + 1])
    return acc
