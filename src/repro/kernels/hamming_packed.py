"""Packed-binary hypervector similarity: XOR + popcount on uint32 lanes.

Inference-side unary machinery (uHD contributions 3/4 at classification
time): binarized hypervectors are stored 32 dims/word; the ±1 dot
product is  d - 2 * popcount(q ^ c).  The VPU's native
``population_count`` is the paper's popcounter circuit.

Grid (B/bt, C/ct); the word axis W is small (D/32 <= 512 for D <= 16K)
and kept whole per block, so each (bt, ct) tile is one VMEM-resident
broadcast XOR + popcount + reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hamming_kernel(q_ref, c_ref, o_ref, *, d: int):
    q = q_ref[...]  # (bt, W) uint32
    c = c_ref[...]  # (ct, W) uint32
    x = q[:, None, :] ^ c[None, :, :]
    pc = jax.lax.population_count(x).astype(jnp.int32).sum(-1)
    o_ref[...] = d - 2 * pc


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def hamming_packed_pallas(
    q_words: jax.Array,
    c_words: jax.Array,
    d: int,
    *,
    block_b: int = 128,
    block_c: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, W) uint32, c: (C, W) uint32 -> (B, C) int32 scores.

    B and C may be arbitrary (a serving request batch, C=10 classes):
    operands are zero-padded up to the block grid and the result is
    sliced back — padded rows cost grid cells but never leak scores.
    """
    b, w = q_words.shape
    c, w2 = c_words.shape
    assert w == w2
    bp, cp = round_up(b, block_b), round_up(c, block_c)
    if bp != b:
        q_words = jnp.pad(q_words, ((0, bp - b), (0, 0)))
    if cp != c:
        c_words = jnp.pad(c_words, ((0, cp - c), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_hamming_kernel, d=d),
        grid=(bp // block_b, cp // block_c),
        in_specs=[
            pl.BlockSpec((block_b, w), lambda i, j: (i, 0)),
            pl.BlockSpec((block_c, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, cp), jnp.int32),
        interpret=interpret,
    )(q_words, c_words)
    return out[:b, :c]
