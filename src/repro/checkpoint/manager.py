"""Fault-tolerant checkpointing: atomic, async, elastic-restorable.

Layout (one directory per step):

    <root>/step_000123/
        manifest.json       # tree structure, shapes, dtypes, step, config
        leaf_000000.npy ... # one .npy per pytree leaf (host-gathered)
    <root>/step_000123.tmp/ # staging dir, renamed atomically when complete

Design points for the 1000-node posture:
  * atomicity: writes go to `.tmp` and are renamed only after fsync —
    a preempted job never leaves a half checkpoint that restore would
    pick up;
  * async: `save(..., blocking=False)` snapshots device arrays to host
    (cheap) and writes on a daemon thread, overlapping the next step;
  * elasticity: restore() takes an optional pytree of NamedShardings —
    arrays are device_put to the *new* mesh, so a job restarted on a
    different device count resumes from the same file set;
  * retention: keep_n newest checkpoints are retained, older ones GC'd
    on every publish — including stale `.tmp` staging debris from torn
    attempts once it falls behind the retention window (never the
    latest finalized set, never a live staging dir) — so a periodic
    publisher (e.g. the online learner) runs indefinitely in bounded
    disk; keep_n=0 disables pruning entirely;
  * preemption: install_sigterm_handler() hooks SIGTERM to flush a final
    checkpoint before exit (the standard TPU-preemption contract).

Multi-host: `save_shard(step, local_tree, process_index=i,
process_count=n, shard_axes=...)` lets each host write only the slices
it owns (`leaf_XXXXXX.sNNN.npy`); host 0 stages the manifest and — after
the caller's inter-host barrier — publishes atomically with
`finalize_shards(step)`.  `restore` stitches shard files back together
transparently, so a sharded checkpoint restores on any device count
(the same elasticity contract as the gathered form).
"""

from __future__ import annotations

import json
import re
import shutil
import signal
import threading
import time
from pathlib import Path
from typing import Any, Callable

import numpy as np

import jax

Tree = Any


def _flatten_with_paths(tree: Tree) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, root: str | Path, keep_n: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- write -----------------------------------------------------------

    def save(self, step: int, tree: Tree, *, blocking: bool = True, extra: dict | None = None):
        """Checkpoint `tree` at `step`.  Non-blocking mode snapshots to
        host immediately and writes on a background thread."""
        self.wait()  # one in-flight async save at a time
        flat, _ = _flatten_with_paths(tree)
        host = [(k, np.asarray(jax.device_get(v))) for k, v in flat]

        def write():
            try:
                self._write(step, host, extra or {})
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            write()
            self.wait()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def _write(self, step: int, host: list[tuple[str, np.ndarray]], extra: dict):
        final = self.root / f"step_{step:09d}"
        tmp = self.root / f"step_{step:09d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": [], "extra": extra, "time": time.time()}
        for i, (key, arr) in enumerate(host):
            fname = f"leaf_{i:06d}.npy"
            logical_dtype = str(arr.dtype)
            if logical_dtype == "bfloat16":  # numpy can't persist bf16
                arr = arr.view(np.uint16)
            np.save(tmp / fname, arr)
            manifest["leaves"].append(
                {"key": key, "file": fname, "shape": list(arr.shape),
                 "dtype": logical_dtype}
            )
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._gc()

    def save_shard(
        self,
        step: int,
        tree: Tree,
        *,
        process_index: int,
        process_count: int,
        shard_axes: dict[str, int],
        extra: dict | None = None,
    ):
        """Write one host's shard of a multi-host checkpoint.

        `tree` is this host's *local* view: leaves whose flat key appears
        in `shard_axes` (key -> sharded axis) hold this host's slice and
        are written as `leaf_XXXXXX.s{process_index:03d}.npy`; all other
        leaves are replicated and written by host 0 only, which also
        stages the manifest (shard metadata: file stem, shard count,
        axis, per-shard shape).  Files land in the step's `.tmp` staging
        dir and stay invisible to readers until `finalize_shards(step)`
        renames it — called by host 0 once every host has returned from
        its `save_shard` (the inter-host barrier is the caller's;
        single-process simulations simply call this once per virtual
        host, then finalize).  Host 0's call also clears any stale
        staging dir from an aborted earlier attempt (`begin_shards`),
        so host 0 must write first — otherwise stale shard files could
        satisfy finalize's completeness check and publish a torn mix of
        two attempts.
        """
        if not 0 <= process_index < process_count:
            raise ValueError(f"process_index {process_index} not in [0, {process_count})")
        tmp = self.root / f"step_{step:09d}.tmp"
        if process_index == 0:
            self.begin_shards(step)
        else:
            tmp.mkdir(parents=True, exist_ok=True)
        flat, _ = _flatten_with_paths(tree)
        unknown = set(shard_axes) - {k for k, _ in flat}
        if unknown:
            raise KeyError(f"shard_axes names unknown leaves: {sorted(unknown)}")
        manifest = {
            "step": step, "leaves": [], "extra": extra or {},
            "time": time.time(), "process_count": process_count,
        }
        for i, (key, leaf) in enumerate(flat):
            sharded = key in shard_axes
            if not sharded and process_index != 0:
                # replicated leaf, host 0's to write: skip the
                # device->host transfer entirely
                continue
            arr = np.asarray(jax.device_get(leaf))
            logical_dtype = str(arr.dtype)
            if logical_dtype == "bfloat16":  # numpy can't persist bf16
                arr = arr.view(np.uint16)
            if sharded:
                np.save(tmp / f"leaf_{i:06d}.s{process_index:03d}.npy", arr)
            else:
                np.save(tmp / f"leaf_{i:06d}.npy", arr)
            if process_index == 0:  # only host 0's manifest is ever written
                meta = {"key": key, "file": f"leaf_{i:06d}.npy",
                        "shape": list(arr.shape), "dtype": logical_dtype}
                if sharded:
                    meta.update(
                        file=f"leaf_{i:06d}", shards=process_count,
                        axis=int(shard_axes[key]),
                    )
                manifest["leaves"].append(meta)
        if process_index == 0:
            with open(tmp / "manifest.json", "w") as f:
                json.dump(manifest, f)
                f.flush()

    def begin_shards(self, step: int):
        """Start a sharded save attempt: clear any stale staging dir left
        by an aborted earlier attempt, so finalize_shards can never
        publish a checkpoint mixing shard files from two attempts.  Host
        0's `save_shard` calls this implicitly; in a real multi-host
        deployment host 0 must therefore run (or `begin_shards` be
        called) *before* the barrier that releases the other hosts'
        writes."""
        tmp = self.root / f"step_{step:09d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

    def finalize_shards(self, step: int):
        """Atomic publish of a sharded save: verify every file the staged
        manifest lists exists (a missing shard means a host has not
        written yet — refuse loudly rather than publish a torn
        checkpoint), then rename `.tmp` -> final."""
        tmp = self.root / f"step_{step:09d}.tmp"
        manifest_path = tmp / "manifest.json"
        if not manifest_path.exists():
            raise FileNotFoundError(
                f"no staged manifest for step {step} under {tmp} "
                "(host 0 has not called save_shard yet)"
            )
        manifest = json.loads(manifest_path.read_text())
        missing = []
        for m in manifest["leaves"]:
            if "shards" in m:
                missing += [
                    f"{m['file']}.s{s:03d}.npy"
                    for s in range(m["shards"])
                    if not (tmp / f"{m['file']}.s{s:03d}.npy").exists()
                ]
            elif not (tmp / m["file"]).exists():
                missing.append(m["file"])
        if missing:
            raise FileNotFoundError(
                f"step {step} is missing shard files {missing[:8]} — every "
                "host must save_shard before finalize_shards publishes"
            )
        final = self.root / f"step_{step:09d}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        """Prune-on-publish retention: keep the `keep_n` newest finalized
        sets (the latest is always among them, so a reader never loses
        its floor), and collect stale `.tmp` staging dirs left by torn
        or aborted attempts once their step falls behind the retention
        window.  Torn-shard-safe: any *live* staging attempt is at a
        step >= the latest finalized one (steps publish monotonically),
        so a `.tmp` strictly older than the oldest kept step can never
        be an in-flight save — only debris that `finalize_shards` would
        refuse anyway.  `keep_n=0` keeps everything and prunes nothing;
        the online learner's periodic publishing relies on this GC to
        run indefinitely in bounded disk.
        """
        if not self.keep_n:
            return
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep_n]:
            shutil.rmtree(self.root / f"step_{s:09d}", ignore_errors=True)
        kept = steps[-self.keep_n :]
        if not kept:
            return
        for p in self.root.iterdir():
            m = re.fullmatch(r"step_(\d+)\.tmp", p.name)
            if m and int(m.group(1)) < kept[0]:
                shutil.rmtree(p, ignore_errors=True)

    # -- read ------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for p in self.root.iterdir():
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def poll_latest(self, after: int | None = None) -> int | None:
        """Newest complete step strictly newer than `after`, else None.

        The hot-reload poll: serving watches a checkpoint directory and
        swaps engines only when the trainer has published (atomically
        renamed) a step it has not loaded yet.  `after=None` degrades to
        `latest_step`.
        """
        latest = self.latest_step()
        if latest is None or (after is not None and latest <= after):
            return None
        return latest

    def restore(
        self,
        step: int,
        like: Tree,
        *,
        shardings: Tree | None = None,
    ) -> Tree:
        """Restore into the structure of `like`.  `shardings` (a matching
        tree of NamedSharding) re-lays the arrays onto the current mesh —
        restoring onto a different mesh/device count is supported
        (elastic restart)."""
        d = self.root / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        by_key = {m["key"]: m for m in manifest["leaves"]}
        flat, treedef = _flatten_with_paths(like)
        shard_flat = None
        if shardings is not None:
            shard_flat = [s for _, s in _flatten_with_paths(shardings)[0]]
        leaves = []
        for i, (key, leaf) in enumerate(flat):
            meta = by_key.get(key)
            if meta is None:
                raise KeyError(f"checkpoint {step} missing leaf {key!r}")
            if meta.get("shards"):  # stitch per-host shard files
                arr = np.concatenate(
                    [
                        np.load(d / f"{meta['file']}.s{s:03d}.npy")
                        for s in range(meta["shards"])
                    ],
                    axis=meta["axis"],
                )
            else:
                arr = np.load(d / meta["file"])
            if meta["dtype"] == "bfloat16":
                import ml_dtypes

                arr = arr.view(ml_dtypes.bfloat16)
            want = tuple(getattr(leaf, "shape", arr.shape))
            if tuple(arr.shape) != want:
                raise ValueError(f"{key}: checkpoint shape {arr.shape} != {want}")
            if shard_flat is not None:
                leaves.append(jax.device_put(arr, shard_flat[i]))
            else:
                leaves.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])

    def extra(self, step: int) -> dict:
        d = self.root / f"step_{step:09d}"
        return json.loads((d / "manifest.json").read_text()).get("extra", {})

    def leaf_meta(self, step: int) -> dict[str, dict]:
        """Manifest metadata per flat leaf key (shape/dtype/shard info) —
        lets callers adapt their restore template to what a checkpoint
        actually stores (e.g. pre-split-counter scalar `n_seen`)."""
        d = self.root / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        return {m["key"]: m for m in manifest["leaves"]}


def install_sigterm_handler(save_fn: Callable[[], None]):
    """Preemption hook: checkpoint then exit(0) on SIGTERM."""

    def handler(signum, frame):
        save_fn()
        raise SystemExit(0)

    signal.signal(signal.SIGTERM, handler)
