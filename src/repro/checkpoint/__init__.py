from repro.checkpoint.manager import CheckpointManager, install_sigterm_handler  # noqa: F401
